//! Pluggable rank-to-rank byte transports for the data-parallel group.
//!
//! A [`Transport`] gives one rank a point-to-point message channel to
//! every peer, with per-link byte/message counters — the *measured*
//! communication volume that `netsim`'s analytic ring model is
//! calibrated against (DESIGN.md §Distributed execution). Two
//! implementations, both std-only:
//!
//! * [`mem_mesh`] — an in-process channel mesh (`std::sync::mpsc`), one
//!   FIFO per ordered rank pair; the default for tests/benches and the
//!   fastest path for single-host multi-rank runs;
//! * [`tcp_mesh`] — a TCP-loopback mesh over `std::net`: ephemeral
//!   127.0.0.1 ports (no fixed-port collisions in CI), a full mesh of
//!   length-prefix-framed streams, one reader thread per link draining
//!   frames into a per-peer inbox so sends never deadlock against a
//!   peer that is still computing.
//!
//! Both transports deliver per-link FIFO ordering; the collectives
//! (`dist::collective`) only ever match a receive to a specific peer,
//! so results are independent of cross-link timing — determinism comes
//! from the schedule, not the transport.
//!
//! Counters are split into two traffic classes: [`Class::Data`] is the
//! gradient-sync payload (what the wire-volume calibration and the
//! `AllreduceReport` accounting cross-check cover), [`Class::Diag`] is
//! metrics-only traffic — the full-gradient gathers behind the Fig.-10
//! relative-error diagnostic, which a production build would skip and
//! which therefore must not pollute the calibrated byte counts.
//!
//! Both transports apply the rank's active wire [`Codec`] on `send` and
//! undo it on `recv` (DESIGN.md §Layered wire stack): callers exchange
//! *logical* bytes, while each [`LinkStats`] records the logical and
//! the post-codec wire size side by side. `Codec::Off` (the default)
//! bypasses encoding entirely — raw payload bytes on the wire, and
//! `wire == logical` in every counter.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use super::codec::{self, Codec, Lane};
use super::error::DistError;
use crate::ensure;
use crate::util::error::{Context, EdgcError, Result};

/// Upper bound on a single frame's payload (sanity guard against a
/// corrupted length prefix on the TCP path).
const MAX_FRAME: usize = 1 << 30;

/// Which accounting bucket traffic lands in (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Class {
    /// Gradient-sync payload: counted by the wire-volume calibration.
    Data,
    /// Metrics-only traffic (diagnostic gathers): excluded from it.
    Diag,
}

/// Byte/message counters for one directed link pair (this rank ↔ peer).
///
/// `*_bytes` are **logical** payload bytes — what the caller handed to
/// `send` / got back from `recv`, and what `netsim`'s analytic models
/// price. `*_wire_bytes` are what actually crossed the link after the
/// active codec (equal to logical when `Codec::Off`); the ratio of the
/// two is the measured compression ratio surfaced in the run report.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkStats {
    pub sent_bytes: u64,
    pub sent_wire_bytes: u64,
    pub sent_msgs: u64,
    pub recv_bytes: u64,
    pub recv_wire_bytes: u64,
    pub recv_msgs: u64,
}

/// Per-peer, per-class counters owned by one rank's transport.
#[derive(Clone, Debug)]
pub struct Counters {
    class: Class,
    /// Indexed by peer rank (the own-rank slot stays zero).
    pub data: Vec<LinkStats>,
    pub diag: Vec<LinkStats>,
}

impl Counters {
    fn new(world: usize) -> Counters {
        Counters {
            class: Class::Data,
            data: vec![LinkStats::default(); world],
            diag: vec![LinkStats::default(); world],
        }
    }

    /// Rebuild a counter snapshot from per-link stats restored out of a
    /// checkpoint (`ckpt::state`). The active class resets to `Data` —
    /// a restored snapshot is a baseline to `merge` live traffic into,
    /// not a live accounting bucket.
    pub fn from_links(data: Vec<LinkStats>, diag: Vec<LinkStats>) -> Counters {
        assert_eq!(data.len(), diag.len(), "counter planes of different worlds");
        Counters { class: Class::Data, data, diag }
    }

    pub fn class(&self) -> Class {
        self.class
    }

    pub fn set_class(&mut self, class: Class) {
        self.class = class;
    }

    fn bucket(&mut self) -> &mut Vec<LinkStats> {
        match self.class {
            Class::Data => &mut self.data,
            Class::Diag => &mut self.diag,
        }
    }

    fn on_send(&mut self, to: usize, logical: usize, wire: usize) {
        let l = &mut self.bucket()[to];
        l.sent_bytes += logical as u64;
        l.sent_wire_bytes += wire as u64;
        l.sent_msgs += 1;
    }

    fn on_recv(&mut self, from: usize, logical: usize, wire: usize) {
        let l = &mut self.bucket()[from];
        l.recv_bytes += logical as u64;
        l.recv_wire_bytes += wire as u64;
        l.recv_msgs += 1;
    }

    /// Total logical payload bytes this rank sent on the data class.
    pub fn data_sent_bytes(&self) -> u64 {
        self.data.iter().map(|l| l.sent_bytes).sum()
    }

    /// Total post-codec bytes this rank put on the wire, data class.
    pub fn data_sent_wire_bytes(&self) -> u64 {
        self.data.iter().map(|l| l.sent_wire_bytes).sum()
    }

    /// Total data-class messages this rank sent.
    pub fn data_sent_msgs(&self) -> u64 {
        self.data.iter().map(|l| l.sent_msgs).sum()
    }

    /// Total logical payload bytes this rank sent on the diag class.
    pub fn diag_sent_bytes(&self) -> u64 {
        self.diag.iter().map(|l| l.sent_bytes).sum()
    }

    /// Total post-codec bytes this rank put on the wire, diag class.
    pub fn diag_sent_wire_bytes(&self) -> u64 {
        self.diag.iter().map(|l| l.sent_wire_bytes).sum()
    }

    /// Fold another snapshot's per-link stats into this one, class by
    /// class. Used by overlapped runs, where one rank's traffic splits
    /// across two planes (the p2p/control mesh the compute thread owns
    /// and the collective mesh the comm thread owns) with identical
    /// rank indexing: the merged snapshot is what the wire-volume
    /// calibration compares against the sequential path.
    pub fn merge(&mut self, other: &Counters) {
        assert_eq!(self.data.len(), other.data.len(), "merging counters of different worlds");
        for (bucket, obucket) in
            [(&mut self.data, &other.data), (&mut self.diag, &other.diag)]
        {
            for (l, o) in bucket.iter_mut().zip(obucket.iter()) {
                l.sent_bytes += o.sent_bytes;
                l.sent_wire_bytes += o.sent_wire_bytes;
                l.sent_msgs += o.sent_msgs;
                l.recv_bytes += o.recv_bytes;
                l.recv_wire_bytes += o.recv_wire_bytes;
                l.recv_msgs += o.recv_msgs;
            }
        }
    }
}

/// One rank's endpoint into the group: point-to-point sends/receives
/// with per-link counters. Each rank worker owns its transport
/// exclusively (`&mut self` everywhere), so counters are plain fields.
pub trait Transport: Send {
    fn rank(&self) -> usize;
    fn world(&self) -> usize;
    /// Send one message of *logical* payload bytes to `to`; the active
    /// codec decides what crosses the wire (counters record both; any
    /// transport framing overhead beyond that is transport-internal).
    fn send(&mut self, to: usize, payload: &[u8]) -> Result<()>;
    /// Receive the next message *from a specific peer* (per-link FIFO),
    /// decoded back to logical bytes.
    fn recv(&mut self, from: usize) -> Result<Vec<u8>>;
    fn counters(&self) -> &Counters;
    fn counters_mut(&mut self) -> &mut Counters;
    /// Switch the accounting bucket for subsequent traffic.
    fn set_class(&mut self, class: Class) {
        self.counters_mut().set_class(class);
    }
    /// The wire codec applied to subsequent sends (both directions keep
    /// their own codec state; every rank of a group sets the same one).
    fn codec(&self) -> Codec;
    fn set_codec(&mut self, codec: Codec);
    /// The payload lane subsequent sends carry (see [`Lane`]): lossy
    /// codecs quantize only `Lane::Factor` traffic.
    fn lane(&self) -> Lane;
    fn set_lane(&mut self, lane: Lane);
    /// Deadline for subsequent `recv` calls: `None` (the default)
    /// blocks forever, `Some(d)` surfaces [`DistError::Timeout`] when
    /// no frame arrives within `d`. A deadline turns a silent hang on
    /// a wedged peer into a typed, attributable failure.
    fn set_recv_deadline(&mut self, _deadline: Option<Duration>) {}
    /// What a peer would actually receive if `payload` were sent now —
    /// `Some(quantized)` under a lossy codec/lane pair, `None` when the
    /// wire is bit-exact. Collectives apply this to the chunks they
    /// keep locally (own all-gather chunk, broadcast root copy) so a
    /// lossy codec can never hand the sender a higher-precision copy
    /// than its peers.
    fn lossy_view(&self, payload: &[u8]) -> Option<Vec<u8>> {
        codec::lossy_roundtrip(self.codec(), self.lane(), payload)
    }
}

// ------------------------------------------------------------- subgroups

/// A re-indexed view of a subset of a mesh's ranks, itself a full
/// [`Transport`]: member `i` of the subgroup sees `rank() == i` and
/// `world() == members.len()`, with sends/receives routed to the global
/// ranks behind the scenes. This is how pipeline-parallel training
/// reuses the ring collectives unchanged for each stage's DP subgroup
/// (members = the `dp` workers holding the same stage): the collectives'
/// fold-from-zero-in-local-rank-order determinism contract carries over
/// verbatim, with local rank = DP replica index.
///
/// Counters stay with the underlying transport (per *global* link), so
/// wire-volume calibration sees subgroup traffic exactly where it
/// flowed.
pub struct SubTransport<'a> {
    inner: &'a mut dyn Transport,
    /// Global ranks of the subgroup, ascending; local rank = position.
    members: Vec<usize>,
    /// This rank's local index in `members`.
    me: usize,
}

impl<'a> SubTransport<'a> {
    /// Build the view. `members` must be strictly ascending, within the
    /// mesh, and contain the inner transport's own rank.
    pub fn new(inner: &'a mut dyn Transport, members: Vec<usize>) -> Result<SubTransport<'a>> {
        ensure!(!members.is_empty(), "subgroup must have at least one member");
        ensure!(
            members.windows(2).all(|w| w[0] < w[1]),
            "subgroup members must be strictly ascending: {members:?}"
        );
        ensure!(
            *members.last().unwrap() < inner.world(),
            "subgroup member {} out of world {}",
            members.last().unwrap(),
            inner.world()
        );
        let me = members
            .iter()
            .position(|&m| m == inner.rank())
            .with_context(|| {
                format!("rank {} is not a member of subgroup {members:?}", inner.rank())
            })?;
        Ok(SubTransport { inner, members, me })
    }
}

impl Transport for SubTransport<'_> {
    fn rank(&self) -> usize {
        self.me
    }

    fn world(&self) -> usize {
        self.members.len()
    }

    fn send(&mut self, to: usize, payload: &[u8]) -> Result<()> {
        let g = *self
            .members
            .get(to)
            .with_context(|| format!("subgroup rank {to} out of {}", self.members.len()))?;
        self.inner.send(g, payload)
    }

    fn recv(&mut self, from: usize) -> Result<Vec<u8>> {
        let g = *self
            .members
            .get(from)
            .with_context(|| format!("subgroup rank {from} out of {}", self.members.len()))?;
        self.inner.recv(g)
    }

    fn counters(&self) -> &Counters {
        self.inner.counters()
    }

    fn counters_mut(&mut self) -> &mut Counters {
        self.inner.counters_mut()
    }

    fn codec(&self) -> Codec {
        self.inner.codec()
    }

    fn set_codec(&mut self, codec: Codec) {
        self.inner.set_codec(codec);
    }

    fn lane(&self) -> Lane {
        self.inner.lane()
    }

    fn set_lane(&mut self, lane: Lane) {
        self.inner.set_lane(lane);
    }

    fn set_recv_deadline(&mut self, deadline: Option<Duration>) {
        self.inner.set_recv_deadline(deadline);
    }
}

// ------------------------------------------------------------ in-process

/// The typed error for a link whose peer endpoint is gone, annotated
/// with the observing rank (the inherent `EdgcError::context` keeps the
/// [`DistError`] cause reachable through `EdgcError::dist`).
fn peer_death(me: usize, peer: usize) -> EdgcError {
    EdgcError::from_dist(DistError::PeerDeath { rank: peer }).context(format!("rank {me}"))
}

/// Drain one frame from a per-peer inbox under the optional deadline,
/// mapping the two mpsc failure shapes to their typed causes.
fn inbox_recv<T>(
    rx: &Receiver<T>,
    me: usize,
    from: usize,
    deadline: Option<Duration>,
) -> Result<T> {
    match deadline {
        None => rx.recv().map_err(|_| peer_death(me, from)),
        Some(d) => rx.recv_timeout(d).map_err(|e| match e {
            RecvTimeoutError::Disconnected => peer_death(me, from),
            RecvTimeoutError::Timeout => EdgcError::from_dist(DistError::Timeout {
                rank: from,
                millis: d.as_millis() as u64,
            })
            .context(format!("rank {me}")),
        }),
    }
}

/// Encode `payload` for the wire; `None` means raw passthrough
/// (`Codec::Off` adds no header and no overhead).
fn wire_encode(codec: Codec, lane: Lane, payload: &[u8]) -> Option<Vec<u8>> {
    if codec == Codec::Off {
        None
    } else {
        Some(codec::encode(codec, lane, payload))
    }
}

/// Decode a received wire message back to `(logical_bytes, wire_len)`;
/// a payload the codec rejects is a typed [`DistError::FrameCorrupt`].
fn wire_decode(codec: Codec, msg: Vec<u8>) -> Result<(Vec<u8>, usize)> {
    let wire = msg.len();
    if codec == Codec::Off {
        Ok((msg, wire))
    } else {
        let logical = codec::decode(&msg).map_err(|e| {
            EdgcError::from_dist(DistError::FrameCorrupt { detail: e.to_string() })
        })?;
        Ok((logical, wire))
    }
}

/// In-process mesh endpoint: one unbounded FIFO per ordered rank pair.
pub struct MemTransport {
    rank: usize,
    world: usize,
    peers: Vec<Option<Sender<Vec<u8>>>>,
    inbox: Vec<Option<Receiver<Vec<u8>>>>,
    counters: Counters,
    codec: Codec,
    lane: Lane,
    deadline: Option<Duration>,
}

/// Build the full in-process mesh: `world` endpoints, rank-indexed.
pub fn mem_mesh(world: usize) -> Vec<MemTransport> {
    assert!(world >= 1);
    let mut peers: Vec<Vec<Option<Sender<Vec<u8>>>>> =
        (0..world).map(|_| (0..world).map(|_| None).collect()).collect();
    let mut inbox: Vec<Vec<Option<Receiver<Vec<u8>>>>> =
        (0..world).map(|_| (0..world).map(|_| None).collect()).collect();
    for i in 0..world {
        for j in 0..world {
            if i != j {
                let (tx, rx) = channel();
                peers[i][j] = Some(tx);
                inbox[j][i] = Some(rx);
            }
        }
    }
    peers
        .into_iter()
        .zip(inbox)
        .enumerate()
        .map(|(rank, (peers, inbox))| MemTransport {
            rank,
            world,
            peers,
            inbox,
            counters: Counters::new(world),
            codec: Codec::Off,
            lane: Lane::Frame,
            deadline: None,
        })
        .collect()
}

impl Transport for MemTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&mut self, to: usize, payload: &[u8]) -> Result<()> {
        let tx = self
            .peers
            .get(to)
            .and_then(|p| p.as_ref())
            .with_context(|| format!("rank {}: no link to rank {to}", self.rank))?;
        let wire = match wire_encode(self.codec, self.lane, payload) {
            Some(w) => w,
            None => payload.to_vec(),
        };
        let wire_len = wire.len();
        // a dropped receiver means the peer's transport is gone
        tx.send(wire).map_err(|_| peer_death(self.rank, to))?;
        self.counters.on_send(to, payload.len(), wire_len);
        Ok(())
    }

    fn recv(&mut self, from: usize) -> Result<Vec<u8>> {
        let rx = self
            .inbox
            .get(from)
            .and_then(|p| p.as_ref())
            .with_context(|| format!("rank {}: no link from rank {from}", self.rank))?;
        let msg = inbox_recv(rx, self.rank, from, self.deadline)?;
        let (logical, wire_len) = wire_decode(self.codec, msg)?;
        self.counters.on_recv(from, logical.len(), wire_len);
        Ok(logical)
    }

    fn counters(&self) -> &Counters {
        &self.counters
    }

    fn counters_mut(&mut self) -> &mut Counters {
        &mut self.counters
    }

    fn codec(&self) -> Codec {
        self.codec
    }

    fn set_codec(&mut self, codec: Codec) {
        self.codec = codec;
    }

    fn lane(&self) -> Lane {
        self.lane
    }

    fn set_lane(&mut self, lane: Lane) {
        self.lane = lane;
    }

    fn set_recv_deadline(&mut self, deadline: Option<Duration>) {
        self.deadline = deadline;
    }
}

// ----------------------------------------------------------- tcp mesh

/// TCP-loopback mesh endpoint (see module docs for the framing and the
/// per-link reader threads).
pub struct TcpTransport {
    rank: usize,
    world: usize,
    /// Write side of each link (reader threads own clones).
    streams: Vec<Option<TcpStream>>,
    inbox: Vec<Option<Receiver<ReaderFrame>>>,
    counters: Counters,
    codec: Codec,
    lane: Lane,
    deadline: Option<Duration>,
}

/// One inbox item: a drained frame, or the reader's reason for refusing
/// one (an impossible length prefix) — surfaced by `recv` as
/// [`DistError::FrameCorrupt`] rather than a silent link teardown.
type ReaderFrame = std::result::Result<Vec<u8>, String>;

fn reader_loop(mut stream: TcpStream, tx: Sender<ReaderFrame>) {
    loop {
        let mut lenb = [0u8; 4];
        if stream.read_exact(&mut lenb).is_err() {
            return; // peer closed: inbox channel drops, recv() errors
        }
        let len = u32::from_le_bytes(lenb) as usize;
        if len > MAX_FRAME {
            tx.send(Err(format!("length prefix {len} exceeds MAX_FRAME {MAX_FRAME}"))).ok();
            return;
        }
        let mut buf = vec![0u8; len];
        if stream.read_exact(&mut buf).is_err() || tx.send(Ok(buf)).is_err() {
            return;
        }
    }
}

/// Build the full TCP-loopback mesh: `world` listeners on ephemeral
/// 127.0.0.1 ports, one framed stream per rank pair (rank j dials rank
/// i for i < j, identifying itself with a 4-byte rank handshake).
pub fn tcp_mesh(world: usize) -> Result<Vec<TcpTransport>> {
    assert!(world >= 1);
    let listeners: Vec<TcpListener> = (0..world)
        .map(|_| TcpListener::bind("127.0.0.1:0"))
        .collect::<std::io::Result<_>>()
        .context("binding loopback listeners")?;
    let addrs: Vec<SocketAddr> = listeners
        .iter()
        .map(|l| l.local_addr())
        .collect::<std::io::Result<_>>()
        .context("resolving listener addrs")?;

    // streams[i][j]: rank i's stream to peer j. Dials land in the
    // listener backlog, so dial-then-accept from one thread is safe.
    let mut streams: Vec<Vec<Option<TcpStream>>> =
        (0..world).map(|_| (0..world).map(|_| None).collect()).collect();
    for i in 0..world {
        for j in (i + 1)..world {
            let mut s = TcpStream::connect(addrs[i])
                .with_context(|| format!("rank {j} dialing rank {i}"))?;
            s.set_nodelay(true)?;
            s.write_all(&(j as u32).to_le_bytes())?;
            streams[j][i] = Some(s);
        }
        for _ in (i + 1)..world {
            let (mut s, _) = listeners[i].accept().with_context(|| format!("rank {i} accept"))?;
            s.set_nodelay(true)?;
            let mut idb = [0u8; 4];
            s.read_exact(&mut idb)?;
            let peer = u32::from_le_bytes(idb) as usize;
            ensure!(peer > i && peer < world, "bad handshake rank {peer} at rank {i}");
            ensure!(streams[i][peer].is_none(), "duplicate link {i} <- {peer}");
            streams[i][peer] = Some(s);
        }
    }

    let mut out = Vec::with_capacity(world);
    for (rank, row) in streams.into_iter().enumerate() {
        let mut inbox = Vec::with_capacity(world);
        let mut writers = Vec::with_capacity(world);
        for stream in row {
            match stream {
                Some(s) => {
                    let (tx, rx) = channel();
                    let rs = s.try_clone().context("cloning stream for reader")?;
                    std::thread::spawn(move || reader_loop(rs, tx));
                    inbox.push(Some(rx));
                    writers.push(Some(s));
                }
                None => {
                    inbox.push(None);
                    writers.push(None);
                }
            }
        }
        out.push(TcpTransport {
            rank,
            world,
            streams: writers,
            inbox,
            counters: Counters::new(world),
            codec: Codec::Off,
            lane: Lane::Frame,
            deadline: None,
        });
    }
    Ok(out)
}

impl Transport for TcpTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&mut self, to: usize, payload: &[u8]) -> Result<()> {
        let encoded = wire_encode(self.codec, self.lane, payload);
        let wire: &[u8] = encoded.as_deref().unwrap_or(payload);
        if wire.len() > MAX_FRAME {
            return Err(EdgcError::from_dist(DistError::FrameCorrupt {
                detail: format!("frame of {} wire bytes exceeds MAX_FRAME", wire.len()),
            }));
        }
        let s = self
            .streams
            .get_mut(to)
            .and_then(|p| p.as_mut())
            .with_context(|| format!("rank {}: no link to rank {to}", self.rank))?;
        // a write failure on an established loopback link means the
        // peer endpoint is gone (connection reset / shutdown)
        s.write_all(&(wire.len() as u32).to_le_bytes())
            .and_then(|_| s.write_all(wire))
            .map_err(|e| peer_death(self.rank, to).context(format!("send ({e})")))?;
        self.counters.on_send(to, payload.len(), wire.len());
        Ok(())
    }

    fn recv(&mut self, from: usize) -> Result<Vec<u8>> {
        let rx = self
            .inbox
            .get(from)
            .and_then(|p| p.as_ref())
            .with_context(|| format!("rank {}: no link from rank {from}", self.rank))?;
        let msg = match inbox_recv(rx, self.rank, from, self.deadline)? {
            Ok(buf) => buf,
            Err(detail) => {
                return Err(EdgcError::from_dist(DistError::FrameCorrupt { detail })
                    .context(format!("rank {}: recv from rank {from}", self.rank)))
            }
        };
        let (logical, wire_len) = wire_decode(self.codec, msg)?;
        self.counters.on_recv(from, logical.len(), wire_len);
        Ok(logical)
    }

    fn counters(&self) -> &Counters {
        &self.counters
    }

    fn counters_mut(&mut self) -> &mut Counters {
        &mut self.counters
    }

    fn codec(&self) -> Codec {
        self.codec
    }

    fn set_codec(&mut self, codec: Codec) {
        self.codec = codec;
    }

    fn lane(&self) -> Lane {
        self.lane
    }

    fn set_lane(&mut self, lane: Lane) {
        self.lane = lane;
    }

    fn set_recv_deadline(&mut self, deadline: Option<Duration>) {
        self.deadline = deadline;
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        // Unblock peers' reader threads (EOF) so a failing rank cannot
        // leave the rest of the group stuck in recv().
        for s in self.streams.iter().flatten() {
            s.shutdown(Shutdown::Both).ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ping_pong(mut mesh: Vec<impl Transport>) {
        let b = mesh.pop().unwrap();
        let a = mesh.pop().unwrap();
        let (mut a, mut b) = (a, b);
        std::thread::scope(|s| {
            s.spawn(move || {
                a.send(1, b"ping").unwrap();
                assert_eq!(a.recv(1).unwrap(), b"pong");
                assert_eq!(a.counters().data[1].sent_bytes, 4);
                // Codec::Off: wire bytes == logical bytes
                assert_eq!(a.counters().data[1].sent_wire_bytes, 4);
                assert_eq!(a.counters().data[1].recv_wire_bytes, 4);
                assert_eq!(a.counters().data[1].recv_msgs, 1);
            });
            s.spawn(move || {
                assert_eq!(b.recv(0).unwrap(), b"ping");
                b.send(0, b"pong").unwrap();
            });
        });
    }

    #[test]
    fn mem_ping_pong_counts() {
        ping_pong(mem_mesh(2));
    }

    #[test]
    fn tcp_ping_pong_counts() {
        ping_pong(tcp_mesh(2).unwrap());
    }

    fn lossless_codec_link(mut mesh: Vec<impl Transport>) {
        let b = mesh.pop().unwrap();
        let a = mesh.pop().unwrap();
        let (mut a, mut b) = (a, b);
        a.set_codec(Codec::Lossless);
        b.set_codec(Codec::Lossless);
        let payload = vec![0u8; 4096]; // highly compressible
        std::thread::scope(|s| {
            s.spawn(move || {
                a.send(1, &payload).unwrap();
                a.send(1, &[]).unwrap(); // zero-length frames survive
                let l = a.counters().data[1];
                assert_eq!(l.sent_bytes, 4096);
                assert!(
                    l.sent_wire_bytes < 4096 && l.sent_wire_bytes >= codec::CODEC_HEADER_BYTES as u64,
                    "wire {} for 4096 logical",
                    l.sent_wire_bytes
                );
                assert_eq!(a.recv(1).unwrap(), b"done");
            });
            s.spawn(move || {
                assert_eq!(b.recv(0).unwrap(), vec![0u8; 4096]);
                assert_eq!(b.recv(0).unwrap(), Vec::<u8>::new());
                let l = b.counters().data[0];
                assert_eq!(l.recv_bytes, 4096);
                assert!(l.recv_wire_bytes < 4096);
                b.send(0, b"done").unwrap();
            });
        });
    }

    #[test]
    fn mem_lossless_codec_shrinks_wire_counts() {
        lossless_codec_link(mem_mesh(2));
    }

    #[test]
    fn tcp_lossless_codec_shrinks_wire_counts() {
        lossless_codec_link(tcp_mesh(2).unwrap());
    }

    #[test]
    fn bf16_quantizes_factor_lane_only() {
        let mut mesh = mem_mesh(2);
        let mut b = mesh.pop().unwrap();
        let mut a = mesh.pop().unwrap();
        a.set_codec(Codec::Bf16);
        b.set_codec(Codec::Bf16);
        let payload: Vec<u8> =
            [1.0f32, 1.001, -0.333, 7.5].iter().flat_map(|x| x.to_le_bytes()).collect();
        a.set_lane(Lane::Factor);
        let expect = a.lossy_view(&payload).unwrap();
        a.send(1, &payload).unwrap();
        let got = b.recv(0).unwrap();
        assert_eq!(got, expect); // lossy_view is exactly what peers see
        assert_ne!(got, payload);
        assert_eq!(a.counters().data[1].sent_bytes, 16);
        assert_eq!(
            a.counters().data[1].sent_wire_bytes,
            (codec::CODEC_HEADER_BYTES + 8) as u64
        );
        // back on the frame lane everything is bit-exact again
        a.set_lane(Lane::Frame);
        assert!(a.lossy_view(&payload).is_none());
        a.send(1, &payload).unwrap();
        assert_eq!(b.recv(0).unwrap(), payload);
    }

    #[test]
    fn per_link_fifo_order() {
        let mut mesh = mem_mesh(2);
        let mut b = mesh.pop().unwrap();
        let mut a = mesh.pop().unwrap();
        a.send(1, b"first").unwrap();
        a.send(1, b"second").unwrap();
        assert_eq!(b.recv(0).unwrap(), b"first");
        assert_eq!(b.recv(0).unwrap(), b"second");
    }

    #[test]
    fn diag_class_counts_separately() {
        let mut mesh = mem_mesh(2);
        let mut b = mesh.pop().unwrap();
        let mut a = mesh.pop().unwrap();
        a.send(1, &[0u8; 10]).unwrap();
        a.set_class(Class::Diag);
        a.send(1, &[0u8; 100]).unwrap();
        a.set_class(Class::Data);
        assert_eq!(a.counters().data_sent_bytes(), 10);
        assert_eq!(a.counters().diag_sent_bytes(), 100);
        b.recv(0).unwrap();
        b.set_class(Class::Diag);
        b.recv(0).unwrap();
        assert_eq!(b.counters().data[0].recv_bytes, 10);
        assert_eq!(b.counters().diag[0].recv_bytes, 100);
    }

    #[test]
    fn subgroup_reindexes_and_routes() {
        // Global mesh of 4; subgroup {1, 3}: local 0 <-> global 1.
        let mut mesh = mem_mesh(4);
        let t3 = mesh.pop().unwrap();
        let _t2 = mesh.pop().unwrap();
        let t1 = mesh.pop().unwrap();
        let (mut a, mut b) = (t1, t3);
        std::thread::scope(|s| {
            s.spawn(move || {
                let mut sub = SubTransport::new(&mut a, vec![1, 3]).unwrap();
                assert_eq!(sub.rank(), 0);
                assert_eq!(sub.world(), 2);
                sub.send(1, b"hi").unwrap();
                assert_eq!(sub.recv(1).unwrap(), b"yo");
                // counters live on the global link to rank 3
                assert_eq!(a.counters().data[3].sent_bytes, 2);
                assert_eq!(a.counters().data[3].recv_bytes, 2);
            });
            s.spawn(move || {
                let mut sub = SubTransport::new(&mut b, vec![1, 3]).unwrap();
                assert_eq!(sub.rank(), 1);
                assert_eq!(sub.recv(0).unwrap(), b"hi");
                sub.send(0, b"yo").unwrap();
            });
        });
    }

    #[test]
    fn subgroup_rejects_bad_membership() {
        let mut mesh = mem_mesh(3);
        let mut t0 = mesh.remove(0);
        // own rank missing
        assert!(SubTransport::new(&mut t0, vec![1, 2]).is_err());
        // out of world
        assert!(SubTransport::new(&mut t0, vec![0, 5]).is_err());
        // not ascending
        assert!(SubTransport::new(&mut t0, vec![2, 0]).is_err());
        // empty
        assert!(SubTransport::new(&mut t0, vec![]).is_err());
        // valid singleton
        assert!(SubTransport::new(&mut t0, vec![0]).is_ok());
    }

    #[test]
    fn counters_merge_adds_per_link_per_class() {
        let mut mesh = mem_mesh(2);
        let mut b = mesh.pop().unwrap();
        let mut a = mesh.pop().unwrap();
        a.send(1, &[0u8; 7]).unwrap();
        a.set_class(Class::Diag);
        a.send(1, &[0u8; 11]).unwrap();
        b.recv(0).unwrap();
        b.set_class(Class::Diag);
        b.recv(0).unwrap();
        let mut merged = a.counters().clone();
        merged.merge(b.counters());
        assert_eq!(merged.data[1].sent_bytes, 7);
        assert_eq!(merged.data[0].recv_bytes, 7);
        assert_eq!(merged.diag[1].sent_bytes, 11);
        assert_eq!(merged.diag[0].recv_bytes, 11);
        assert_eq!(merged.data[1].sent_msgs + merged.data[0].recv_msgs, 2);
    }

    #[test]
    fn send_to_self_or_out_of_range_errors() {
        let mut mesh = mem_mesh(2);
        let mut a = mesh.remove(0);
        assert!(a.send(0, b"x").is_err());
        assert!(a.send(5, b"x").is_err());
        assert!(a.recv(0).is_err());
    }

    #[test]
    fn closed_tcp_link_errors_instead_of_hanging() {
        let mut mesh = tcp_mesh(2).unwrap();
        let mut b = mesh.pop().unwrap();
        let a = mesh.pop().unwrap();
        drop(a); // shutdown propagates EOF to b's reader
        let err = b.recv(0).unwrap_err();
        assert_eq!(err.dist(), Some(&DistError::PeerDeath { rank: 0 }));
    }

    #[test]
    fn closed_mem_link_is_typed_peer_death() {
        // send into a dropped peer endpoint
        let mut mesh = mem_mesh(2);
        let b = mesh.pop().unwrap();
        let mut a = mesh.remove(0);
        drop(b);
        let err = a.send(1, b"x").unwrap_err();
        assert_eq!(err.dist(), Some(&DistError::PeerDeath { rank: 1 }));
        // recv from a dropped peer endpoint
        let mut mesh = mem_mesh(2);
        let mut b = mesh.pop().unwrap();
        let a = mesh.remove(0);
        drop(a);
        let err = b.recv(0).unwrap_err();
        assert_eq!(err.dist(), Some(&DistError::PeerDeath { rank: 0 }));
        assert!(err.to_string().contains("rank 0"), "{err}");
    }

    #[test]
    fn recv_deadline_surfaces_typed_timeout() {
        let mut mesh = mem_mesh(2);
        let mut b = mesh.pop().unwrap();
        b.set_recv_deadline(Some(Duration::from_millis(10)));
        let err = b.recv(0).unwrap_err();
        assert_eq!(err.dist(), Some(&DistError::Timeout { rank: 0, millis: 10 }));
        // clearing the deadline restores blocking semantics; a queued
        // frame is delivered normally either way
        let mut a = mesh.remove(0);
        a.send(1, b"late").unwrap();
        assert_eq!(b.recv(0).unwrap(), b"late");
        b.set_recv_deadline(None);
        a.send(1, b"again").unwrap();
        assert_eq!(b.recv(0).unwrap(), b"again");
    }
}
