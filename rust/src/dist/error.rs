//! Typed distributed failures.
//!
//! The transports used to report every fault as a rendered string,
//! which forced fault-handling code (and the fault-injection tests) to
//! grep messages. [`DistError`] names the three failure shapes the
//! wire layer can actually produce; it rides inside
//! [`EdgcError`](crate::util::error::EdgcError) (see
//! `EdgcError::dist`), so existing `Result` signatures and context
//! chains are untouched while callers match on the variant:
//!
//! * [`DistError::PeerDeath`] — the link to a peer closed mid-run: the
//!   peer's transport dropped (worker exited, crashed, or was
//!   fault-injected). Collectives block on specific peers, so this is
//!   the error every survivor of a killed rank eventually sees.
//! * [`DistError::FrameCorrupt`] — a frame arrived (or was about to be
//!   sent) that cannot be valid: an oversized length prefix or a wire
//!   codec payload that fails to decode.
//! * [`DistError::Timeout`] — a receive exceeded the transport's
//!   configured deadline (`Transport::set_recv_deadline`); off by
//!   default, so unconfigured groups keep their blocking semantics.

use std::fmt;

/// The typed cause of a transport-layer failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DistError {
    /// The link to `rank` closed: that peer's transport is gone.
    PeerDeath { rank: usize },
    /// A frame that cannot be decoded or legally sent.
    FrameCorrupt { detail: String },
    /// No frame from `rank` within the configured receive deadline.
    Timeout { rank: usize, millis: u64 },
}

impl fmt::Display for DistError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DistError::PeerDeath { rank } => {
                write!(f, "peer rank {rank} died (link closed)")
            }
            DistError::FrameCorrupt { detail } => write!(f, "corrupt frame: {detail}"),
            DistError::Timeout { rank, millis } => {
                write!(f, "recv from rank {rank} timed out after {millis} ms")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_rank() {
        let e = DistError::PeerDeath { rank: 2 };
        assert!(e.to_string().contains("rank 2"));
        let t = DistError::Timeout { rank: 1, millis: 250 };
        assert!(t.to_string().contains("rank 1") && t.to_string().contains("250"));
        let c = DistError::FrameCorrupt { detail: "bad header".into() };
        assert!(c.to_string().contains("bad header"));
    }
}
