//! Pluggable wire codecs: the layer between the collectives / p2p
//! framing and the byte [`Transport`](super::transport::Transport)s
//! (DESIGN.md §Layered wire stack).
//!
//! Every logical payload a transport ships — P/Q factor chunks from the
//! compressed all-reduce, 1F1B activation/tied-embedding frames, rank
//! broadcasts, diag gathers — passes through the rank's active
//! [`Codec`] on `send` and is decoded back on `recv`. The codec is
//! invisible to callers: collectives and the pipeline keep exchanging
//! *logical* bytes, counters record both the logical and the on-wire
//! size, and `netsim`'s analytic identities keep pricing logical bytes.
//!
//! Two codec families ship in-tree:
//!
//! * **`lossless`** — byte-plane transpose (f32 payloads interleave
//!   sign/exponent and mantissa bytes; splitting index-mod-4 planes
//!   groups the compressible exponent bytes together) followed by the
//!   best of {raw, RLE, canonical order-0 Huffman, delta+Huffman} per
//!   plane, chosen by smallest encoding. Bit-exact by construction, so
//!   it sits *outside* the numerics contract: every run is required to
//!   be byte-identical to `--codec off` (pinned in
//!   `tests/determinism.rs`), it just moves fewer wire bytes.
//! * **`bf16` / `f16`** — round-to-nearest-even quantization of f32
//!   payloads on the [`Lane::Factor`] lane (the PowerSGD P/Q factor
//!   all-reduces tagged by `compress::round_dist`). Lossy: these join
//!   the numerics contract and carry their own determinism pins
//!   (byte-identical across threads × transports × overlap × pp
//!   arrangement at fixed dp). Non-factor lanes fall back to the
//!   lossless codec so control/frame traffic stays bit-exact.
//!
//! Wire format when a codec is active: a [`CODEC_HEADER_BYTES`]-byte
//! header `[method: u8][logical_len: u32 LE]` followed by the method's
//! body. The header is self-describing — the receiver needs no lane or
//! codec state, and any encoder may fall back to `method = raw` when
//! compression would not shrink the payload (so the worst case is
//! `logical + 5` wire bytes). `Codec::Off` bypasses this module
//! entirely: raw payload bytes on the wire, zero overhead, exactly the
//! pre-codec framing.
//!
//! Determinism: every choice an encoder makes (plane mode selection,
//! Huffman tie-breaks, RLE run boundaries) is a pure function of the
//! payload bytes, so identical logical bytes produce identical wire
//! bytes on every transport, thread count and rank layout.

use crate::ensure;
use crate::util::error::Result;

/// Which wire codec a transport applies to outgoing payloads.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Codec {
    /// Raw logical bytes on the wire (no header, no overhead).
    #[default]
    Off,
    /// Bit-exact plane-transpose entropy codec on every lane.
    Lossless,
    /// bf16 RNE quantization of factor payloads; lossless elsewhere.
    Bf16,
    /// IEEE f16 RNE quantization of factor payloads; lossless elsewhere.
    F16,
}

impl Codec {
    pub fn parse(s: &str) -> Result<Codec> {
        match s {
            "off" => Ok(Codec::Off),
            "lossless" => Ok(Codec::Lossless),
            "bf16" => Ok(Codec::Bf16),
            "f16" => Ok(Codec::F16),
            other => Err(crate::err!(
                "unknown codec {other:?} (expected off|lossless|bf16|f16)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Codec::Off => "off",
            Codec::Lossless => "lossless",
            Codec::Bf16 => "bf16",
            Codec::F16 => "f16",
        }
    }

    /// Whether this codec can alter payload values (on the factor lane).
    pub fn is_lossy(&self) -> bool {
        matches!(self, Codec::Bf16 | Codec::F16)
    }
}

/// Payload lane tag: what kind of logical bytes the next sends carry.
/// Mirrors the [`Class`](super::transport::Class) accounting toggle —
/// `compress::round_dist` switches to `Factor` around the P/Q factor
/// all-reduces and restores `Frame` after, so only factor payloads are
/// ever quantized by a lossy codec.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Lane {
    /// Activation/tied/control frames and any other non-factor bytes.
    #[default]
    Frame,
    /// PowerSGD P/Q factor chunks (f32, quantizable).
    Factor,
}

/// `[method: u8][logical_len: u32 LE]` — prepended to every encoded
/// payload when the codec is not `Off`.
pub const CODEC_HEADER_BYTES: usize = 5;

const M_RAW: u8 = 0;
const M_LOSSLESS: u8 = 1;
const M_BF16: u8 = 2;
const M_F16: u8 = 3;

/// Encode `payload` for the wire under `(codec, lane)`. Never called
/// with `Codec::Off` on the hot path — transports pass raw bytes
/// through untouched in that case — but handles it as a raw frame for
/// completeness.
pub fn encode(codec: Codec, lane: Lane, payload: &[u8]) -> Vec<u8> {
    debug_assert!(payload.len() <= u32::MAX as usize, "payload exceeds u32 framing");
    let (method, body) = match (codec, lane) {
        (Codec::Off, _) => (M_RAW, payload.to_vec()),
        (Codec::Bf16, Lane::Factor) if payload.len() % 4 == 0 => (M_BF16, bf16_encode(payload)),
        (Codec::F16, Lane::Factor) if payload.len() % 4 == 0 => (M_F16, f16_encode(payload)),
        _ => match lossless_encode(payload) {
            Some(b) => (M_LOSSLESS, b),
            None => (M_RAW, payload.to_vec()),
        },
    };
    let mut out = Vec::with_capacity(CODEC_HEADER_BYTES + body.len());
    out.push(method);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Decode a wire frame produced by [`encode`] back to logical bytes.
pub fn decode(wire: &[u8]) -> Result<Vec<u8>> {
    ensure!(wire.len() >= CODEC_HEADER_BYTES, "codec frame too short: {} bytes", wire.len());
    let method = wire[0];
    let logical = u32::from_le_bytes([wire[1], wire[2], wire[3], wire[4]]) as usize;
    let body = &wire[CODEC_HEADER_BYTES..];
    match method {
        M_RAW => {
            ensure!(body.len() == logical, "raw frame length {} != header {logical}", body.len());
            Ok(body.to_vec())
        }
        M_LOSSLESS => lossless_decode(body, logical),
        M_BF16 => {
            ensure!(
                logical % 4 == 0 && body.len() == logical / 2,
                "bf16 frame body {} bytes for logical {logical}",
                body.len()
            );
            Ok(bf16_decode(body))
        }
        M_F16 => {
            ensure!(
                logical % 4 == 0 && body.len() == logical / 2,
                "f16 frame body {} bytes for logical {logical}",
                body.len()
            );
            Ok(f16_decode(body))
        }
        other => Err(crate::err!("unknown codec method {other}")),
    }
}

/// The bytes a peer would actually receive if this payload were sent
/// under `(codec, lane)` — i.e. the lossy round-trip — or `None` when
/// the pair is bit-exact. Collectives use this to keep locally exactly
/// what they ship (`all_gather` keeps its own chunk, `broadcast` keeps
/// the root's copy): without it, a lossy codec would hand the sender a
/// higher-precision copy than its peers and desynchronize replicas.
pub fn lossy_roundtrip(codec: Codec, lane: Lane, payload: &[u8]) -> Option<Vec<u8>> {
    match (codec, lane) {
        (Codec::Bf16, Lane::Factor) if payload.len() % 4 == 0 => {
            Some(bf16_decode(&bf16_encode(payload)))
        }
        (Codec::F16, Lane::Factor) if payload.len() % 4 == 0 => {
            Some(f16_decode(&f16_encode(payload)))
        }
        _ => None,
    }
}

// --------------------------------------------------------- quantizers

/// f32 → bf16 with round-to-nearest-even (carry into the exponent —
/// including overflow to inf — falls out of the integer add).
fn f32_bits_to_bf16(bits: u32) -> u16 {
    if (bits >> 23) & 0xff == 0xff {
        // Inf/NaN: truncate; keep NaN signaling a NaN even if its
        // payload lived entirely in the dropped mantissa bits.
        let mut h = (bits >> 16) as u16;
        if bits & 0x007f_ffff != 0 && h & 0x7f == 0 {
            h |= 1;
        }
        return h;
    }
    let round = ((bits >> 16) & 1) + 0x7fff;
    (bits.wrapping_add(round) >> 16) as u16
}

fn bf16_to_f32_bits(h: u16) -> u32 {
    (h as u32) << 16
}

/// f32 → IEEE binary16 with round-to-nearest-even; overflow saturates
/// to ±inf, underflow rounds through the subnormal range to ±0.
fn f32_bits_to_f16(bits: u32) -> u16 {
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32 - 127;
    let man = bits & 0x007f_ffff;
    if exp == 128 {
        // inf / NaN
        let m = (man >> 13) as u16 & 0x3ff;
        return sign | 0x7c00 | if man != 0 { m.max(1) } else { 0 };
    }
    if exp > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if exp >= -14 {
        // normal range: drop 13 mantissa bits with RNE
        let mut m = man >> 13;
        let rem = man & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && m & 1 == 1) {
            m += 1;
        }
        let mut e = (exp + 15) as u32;
        if m == 0x400 {
            m = 0;
            e += 1;
        }
        if e >= 31 {
            return sign | 0x7c00;
        }
        return sign | ((e as u16) << 10) | m as u16;
    }
    if exp >= -25 {
        // subnormal: shift the full 24-bit significand into place
        let full = man | 0x0080_0000;
        let shift = (13 - 14 - exp) as u32; // in 14..=24
        let mut m = full >> shift;
        let rem = full & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        if rem > halfway || (rem == halfway && m & 1 == 1) {
            m += 1;
        }
        if m == 0x400 {
            return sign | (1 << 10); // rounded up to the smallest normal
        }
        return sign | m as u16;
    }
    sign // underflow to zero
}

fn f16_to_f32_bits(h: u16) -> u32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    if exp == 31 {
        return sign | 0x7f80_0000 | (man << 13);
    }
    if exp == 0 {
        if man == 0 {
            return sign;
        }
        // subnormal: normalize into f32's much wider exponent range
        let mut e = -14i32;
        let mut m = man;
        while m & 0x400 == 0 {
            m <<= 1;
            e -= 1;
        }
        return sign | (((e + 127) as u32) << 23) | ((m & 0x3ff) << 13);
    }
    sign | ((exp + 127 - 15) << 23) | (man << 13)
}

fn quant_encode(payload: &[u8], f: impl Fn(u32) -> u16) -> Vec<u8> {
    debug_assert_eq!(payload.len() % 4, 0);
    let mut out = Vec::with_capacity(payload.len() / 2);
    for w in payload.chunks_exact(4) {
        let bits = u32::from_le_bytes([w[0], w[1], w[2], w[3]]);
        out.extend_from_slice(&f(bits).to_le_bytes());
    }
    out
}

fn quant_decode(body: &[u8], f: impl Fn(u16) -> u32) -> Vec<u8> {
    debug_assert_eq!(body.len() % 2, 0);
    let mut out = Vec::with_capacity(body.len() * 2);
    for h in body.chunks_exact(2) {
        let bits = f(u16::from_le_bytes([h[0], h[1]]));
        out.extend_from_slice(&bits.to_le_bytes());
    }
    out
}

fn bf16_encode(payload: &[u8]) -> Vec<u8> {
    quant_encode(payload, f32_bits_to_bf16)
}

fn bf16_decode(body: &[u8]) -> Vec<u8> {
    quant_decode(body, bf16_to_f32_bits)
}

fn f16_encode(payload: &[u8]) -> Vec<u8> {
    quant_encode(payload, f32_bits_to_f16)
}

fn f16_decode(body: &[u8]) -> Vec<u8> {
    quant_decode(body, f16_to_f32_bits)
}

// ---------------------------------------------------- lossless codec

/// Number of interleaved byte planes (one per byte of an f32 word, so
/// exponent/sign bytes — low-entropy on gradient-shaped data — land in
/// one plane and the near-random mantissa bytes in the others).
const PLANES: usize = 4;

const P_RAW: u8 = 0;
const P_RLE: u8 = 1;
const P_HUF: u8 = 2;
const P_DELTA_HUF: u8 = 3;

/// Longest Huffman code we will emit; bounded so the bit-writer's u64
/// accumulator never overflows (7 pending bits + 56 ≤ 64). Realistic
/// plane statistics top out far below this (depth grows ~log_φ of the
/// plane length); a pathological plane falls back to another mode.
const MAX_CODE_LEN: u32 = 56;

/// Encode the whole payload as plane blocks; `None` when the encoding
/// is not strictly smaller than the raw payload (caller sends raw).
fn lossless_encode(payload: &[u8]) -> Option<Vec<u8>> {
    if payload.len() < 16 {
        return None; // header + block framing can't win on tiny frames
    }
    let mut body = Vec::with_capacity(payload.len() / 2);
    for p in 0..PLANES {
        let plane: Vec<u8> = payload.iter().skip(p).step_by(PLANES).copied().collect();
        let mut mode = P_RAW;
        let mut best = plane.clone();
        if let Some(r) = rle_encode(&plane) {
            if r.len() < best.len() {
                mode = P_RLE;
                best = r;
            }
        }
        if let Some(h) = huffman_encode(&plane) {
            if h.len() < best.len() {
                mode = P_HUF;
                best = h;
            }
        }
        let delta = delta_encode(&plane);
        if let Some(h) = huffman_encode(&delta) {
            if h.len() < best.len() {
                mode = P_DELTA_HUF;
                best = h;
            }
        }
        body.push(mode);
        body.extend_from_slice(&(best.len() as u32).to_le_bytes());
        body.extend_from_slice(&best);
    }
    if body.len() < payload.len() {
        Some(body)
    } else {
        None
    }
}

fn lossless_decode(body: &[u8], logical: usize) -> Result<Vec<u8>> {
    let mut planes: Vec<Vec<u8>> = Vec::with_capacity(PLANES);
    let mut at = 0usize;
    for p in 0..PLANES {
        ensure!(body.len() >= at + 5, "lossless frame truncated at plane {p}");
        let mode = body[at];
        let len =
            u32::from_le_bytes([body[at + 1], body[at + 2], body[at + 3], body[at + 4]]) as usize;
        at += 5;
        ensure!(body.len() >= at + len, "lossless plane {p} body truncated");
        let enc = &body[at..at + len];
        at += len;
        let plane_len = (logical + PLANES - 1 - p) / PLANES;
        let plane = match mode {
            P_RAW => {
                ensure!(enc.len() == plane_len, "raw plane {p} length mismatch");
                enc.to_vec()
            }
            P_RLE => rle_decode(enc, plane_len)?,
            P_HUF => huffman_decode(enc, plane_len)?,
            P_DELTA_HUF => delta_decode(&huffman_decode(enc, plane_len)?),
            other => crate::bail!("unknown plane mode {other}"),
        };
        planes.push(plane);
    }
    ensure!(at == body.len(), "trailing bytes after lossless planes");
    let mut out = vec![0u8; logical];
    for (p, plane) in planes.iter().enumerate() {
        for (i, &b) in plane.iter().enumerate() {
            out[p + i * PLANES] = b;
        }
    }
    Ok(out)
}

/// Wrapping byte delta: `d[0] = b[0]`, `d[i] = b[i] - b[i-1]`.
fn delta_encode(plane: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(plane.len());
    let mut prev = 0u8;
    for &b in plane {
        out.push(b.wrapping_sub(prev));
        prev = b;
    }
    out
}

fn delta_decode(deltas: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(deltas.len());
    let mut prev = 0u8;
    for &d in deltas {
        prev = prev.wrapping_add(d);
        out.push(prev);
    }
    out
}

// RLE token stream: control byte `c < 128` → the next `c + 1` bytes are
// literals; `c >= 128` → the next byte repeats `c - 126` times (runs of
// 2..=129). Runs shorter than 3 bytes ride in literal blocks.
fn rle_encode(plane: &[u8]) -> Option<Vec<u8>> {
    let mut out = Vec::with_capacity(plane.len() / 4 + 8);
    let mut lit_start = 0usize;
    let mut i = 0usize;
    while i < plane.len() {
        let mut run = 1usize;
        while i + run < plane.len() && plane[i + run] == plane[i] && run < 129 {
            run += 1;
        }
        if run >= 3 {
            flush_literals(&mut out, &plane[lit_start..i]);
            out.push((run + 126) as u8);
            out.push(plane[i]);
            i += run;
            lit_start = i;
        } else {
            i += run;
        }
        if out.len() >= plane.len() {
            return None; // not going to win; bail early
        }
    }
    flush_literals(&mut out, &plane[lit_start..]);
    if out.len() < plane.len() {
        Some(out)
    } else {
        None
    }
}

fn flush_literals(out: &mut Vec<u8>, mut lits: &[u8]) {
    while !lits.is_empty() {
        let n = lits.len().min(128);
        out.push((n - 1) as u8);
        out.extend_from_slice(&lits[..n]);
        lits = &lits[n..];
    }
}

fn rle_decode(enc: &[u8], expect: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(expect);
    let mut at = 0usize;
    while at < enc.len() {
        let c = enc[at] as usize;
        at += 1;
        if c < 128 {
            let n = c + 1;
            ensure!(enc.len() >= at + n, "rle literal block truncated");
            out.extend_from_slice(&enc[at..at + n]);
            at += n;
        } else {
            ensure!(at < enc.len(), "rle run truncated");
            let n = c - 126;
            let b = enc[at];
            at += 1;
            out.resize(out.len() + n, b);
        }
        ensure!(out.len() <= expect, "rle output exceeds plane length");
    }
    ensure!(out.len() == expect, "rle output {} != plane length {expect}", out.len());
    Ok(out)
}

// Canonical order-0 Huffman. Body: 256 code-length bytes, then the
// MSB-first bitstream (the plane length from the frame header says how
// many symbols to decode, so no terminator is needed).

/// Deterministic code lengths via the two-queue method over symbols
/// sorted by (frequency, symbol); ties always prefer the leaf queue.
fn huffman_lengths(freq: &[u64; 256]) -> Option<[u8; 256]> {
    let mut lens = [0u8; 256];
    let mut leaves: Vec<(u64, usize)> =
        freq.iter().enumerate().filter(|(_, &f)| f > 0).map(|(s, &f)| (f, s)).collect();
    if leaves.is_empty() {
        return Some(lens);
    }
    if leaves.len() == 1 {
        lens[leaves[0].1] = 1;
        return Some(lens);
    }
    leaves.sort(); // by (freq, symbol): the deterministic merge order
    // node: (weight, members) — members tracked as symbol lists so we
    // can bump depths without building an explicit tree (≤256 leaves).
    let mut q1: std::collections::VecDeque<(u64, Vec<usize>)> =
        leaves.iter().map(|&(f, s)| (f, vec![s])).collect();
    let mut q2: std::collections::VecDeque<(u64, Vec<usize>)> = std::collections::VecDeque::new();
    let mut pop_min = |q1: &mut std::collections::VecDeque<(u64, Vec<usize>)>,
                       q2: &mut std::collections::VecDeque<(u64, Vec<usize>)>| {
        match (q1.front(), q2.front()) {
            (Some(a), Some(b)) if b.0 < a.0 => q2.pop_front().unwrap(),
            (Some(_), _) => q1.pop_front().unwrap(),
            (None, Some(_)) => q2.pop_front().unwrap(),
            (None, None) => unreachable!(),
        }
    };
    while q1.len() + q2.len() > 1 {
        let a = pop_min(&mut q1, &mut q2);
        let b = pop_min(&mut q1, &mut q2);
        let mut members = a.1;
        members.extend_from_slice(&b.1);
        for &s in &members {
            lens[s] = lens[s].saturating_add(1);
        }
        q2.push_back((a.0 + b.0, members));
    }
    if lens.iter().any(|&l| l as u32 > MAX_CODE_LEN) {
        return None;
    }
    Some(lens)
}

/// Canonical code assignment: symbols sorted by (length, symbol).
fn canonical_codes(lens: &[u8; 256]) -> [u64; 256] {
    let mut order: Vec<usize> = (0..256).filter(|&s| lens[s] > 0).collect();
    order.sort_by_key(|&s| (lens[s], s));
    let mut codes = [0u64; 256];
    let mut code = 0u64;
    let mut prev_len = 0u8;
    for &s in &order {
        code <<= (lens[s] - prev_len) as u32;
        codes[s] = code;
        code += 1;
        prev_len = lens[s];
    }
    codes
}

fn huffman_encode(plane: &[u8]) -> Option<Vec<u8>> {
    if plane.len() < 300 {
        return None; // the 256-byte table dominates small planes
    }
    let mut freq = [0u64; 256];
    for &b in plane {
        freq[b as usize] += 1;
    }
    let lens = huffman_lengths(&freq)?;
    let codes = canonical_codes(&lens);
    let total_bits: u64 = freq.iter().enumerate().map(|(s, &f)| f * lens[s] as u64).sum();
    let out_len = 256 + total_bits.div_ceil(8) as usize;
    if out_len >= plane.len() {
        return None;
    }
    let mut out = Vec::with_capacity(out_len);
    out.extend_from_slice(&lens);
    let mut acc = 0u64;
    let mut nbits = 0u32;
    for &b in plane {
        let len = lens[b as usize] as u32;
        acc = (acc << len) | codes[b as usize];
        nbits += len;
        while nbits >= 8 {
            out.push((acc >> (nbits - 8)) as u8);
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push((acc << (8 - nbits)) as u8);
    }
    Some(out)
}

fn huffman_decode(enc: &[u8], expect: usize) -> Result<Vec<u8>> {
    ensure!(enc.len() >= 256, "huffman table truncated");
    let mut lens = [0u8; 256];
    lens.copy_from_slice(&enc[..256]);
    let max_len = *lens.iter().max().unwrap() as u32;
    ensure!(expect == 0 || max_len > 0, "huffman table empty for nonempty plane");
    ensure!(max_len <= MAX_CODE_LEN, "huffman code length {max_len} too long");
    // canonical decode tables: per length, the first code, the count,
    // and the offset into the (length, symbol)-sorted symbol list.
    let mut order: Vec<usize> = (0..256).filter(|&s| lens[s] > 0).collect();
    order.sort_by_key(|&s| (lens[s], s));
    let mut first = vec![0u64; (max_len + 2) as usize];
    let mut count = vec![0u64; (max_len + 2) as usize];
    let mut offset = vec![0usize; (max_len + 2) as usize];
    for &s in &order {
        count[lens[s] as usize] += 1;
    }
    let mut code = 0u64;
    let mut off = 0usize;
    for l in 1..=max_len as usize {
        first[l] = code;
        offset[l] = off;
        code = (code + count[l]) << 1;
        off += count[l] as usize;
    }
    let bits = &enc[256..];
    let mut out = Vec::with_capacity(expect);
    let mut at = 0usize; // bit cursor
    while out.len() < expect {
        let mut code = 0u64;
        let mut l = 0usize;
        loop {
            ensure!(at < bits.len() * 8, "huffman bitstream truncated");
            let bit = (bits[at / 8] >> (7 - (at % 8))) & 1;
            at += 1;
            code = (code << 1) | bit as u64;
            l += 1;
            ensure!(l <= max_len as usize, "invalid huffman code");
            if count[l] > 0 && (first[l]..first[l] + count[l]).contains(&code) {
                let idx = offset[l] + (code - first[l]) as usize;
                out.push(order[idx] as u8);
                break;
            }
        }
    }
    ensure!((bits.len() * 8).saturating_sub(at) < 8, "trailing bytes after huffman stream");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(codec: Codec, lane: Lane, payload: &[u8]) -> Vec<u8> {
        let wire = encode(codec, lane, payload);
        decode(&wire).unwrap()
    }

    #[test]
    fn lossless_roundtrips_structured_and_random_payloads() {
        let mut rng = Rng::new(7);
        let cases: Vec<Vec<u8>> = vec![
            vec![],
            vec![42],
            vec![0; 3],
            b"unaligned-frame".to_vec(),
            vec![0u8; 4096],
            (0..4096).map(|i| (i % 7) as u8).collect(),
            (0..5000).map(|_| (rng.below(256)) as u8).collect(),
            (0..1024).flat_map(|i| (0.001f32 * i as f32).to_le_bytes()).collect(),
        ];
        for payload in &cases {
            assert_eq!(&roundtrip(Codec::Lossless, Lane::Frame, payload), payload);
            assert_eq!(&roundtrip(Codec::Lossless, Lane::Factor, payload), payload);
        }
    }

    #[test]
    fn lossless_shrinks_f32_gradient_shaped_payloads() {
        let mut rng = Rng::new(3);
        let payload: Vec<u8> =
            (0..8192).flat_map(|_| (rng.normal() as f32 * 0.01).to_le_bytes()).collect();
        let wire = encode(Codec::Lossless, Lane::Frame, &payload);
        assert!(wire.len() < payload.len(), "wire {} >= logical {}", wire.len(), payload.len());
        assert_eq!(decode(&wire).unwrap(), payload);
    }

    #[test]
    fn lossless_worst_case_overhead_is_bounded() {
        let mut rng = Rng::new(11);
        let payload: Vec<u8> = (0..257).map(|_| rng.below(256) as u8).collect();
        let wire = encode(Codec::Lossless, Lane::Frame, &payload);
        // raw fallback: header only
        assert!(wire.len() <= payload.len() + CODEC_HEADER_BYTES);
        assert_eq!(decode(&wire).unwrap(), payload);
    }

    #[test]
    fn off_and_raw_headers_decode() {
        let wire = encode(Codec::Off, Lane::Frame, b"abc");
        assert_eq!(wire[0], M_RAW);
        assert_eq!(decode(&wire).unwrap(), b"abc");
        assert!(decode(&[M_LOSSLESS]).is_err());
        assert!(decode(&[9, 0, 0, 0, 0]).is_err());
        let mut bad = encode(Codec::Lossless, Lane::Frame, &[7u8; 64]);
        bad.truncate(bad.len() - 1);
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn bf16_roundtrip_is_exhaustively_stable() {
        // every bf16 value decodes to an f32 that re-encodes to itself
        for h in 0..=u16::MAX {
            let f = bf16_to_f32_bits(h);
            assert_eq!(f32_bits_to_bf16(f), h, "bf16 {h:#06x} not a fixed point");
        }
    }

    #[test]
    fn f16_roundtrip_is_exhaustively_stable() {
        for h in 0..=u16::MAX {
            let f = f16_to_f32_bits(h);
            assert_eq!(f32_bits_to_f16(f), h, "f16 {h:#06x} not a fixed point");
        }
    }

    #[test]
    fn bf16_quantization_error_is_half_ulp() {
        let mut rng = Rng::new(5);
        for _ in 0..2000 {
            let x = (rng.normal() as f32) * 3.0;
            let q = f32::from_bits(bf16_to_f32_bits(f32_bits_to_bf16(x.to_bits())));
            assert!(
                (q - x).abs() <= x.abs() / 256.0 + f32::MIN_POSITIVE,
                "bf16({x}) = {q}, err {}",
                (q - x).abs()
            );
        }
    }

    #[test]
    fn f16_matches_known_values() {
        for (x, h) in [
            (0.0f32, 0x0000u16),
            (-0.0, 0x8000),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (65504.0, 0x7bff),
            (1e9, 0x7c00), // overflow -> inf
            (f32::INFINITY, 0x7c00),
            (6.1035156e-5, 0x0400), // smallest normal
            (5.9604645e-8, 0x0001), // smallest subnormal
            (1e-12, 0x0000), // underflow -> 0
        ] {
            assert_eq!(f32_bits_to_f16(x.to_bits()), h, "f16({x})");
        }
        assert!(f32::from_bits(f16_to_f32_bits(f32_bits_to_f16(f32::NAN.to_bits()))).is_nan());
    }

    #[test]
    fn factor_lane_quantizes_and_frame_lane_stays_exact() {
        let payload: Vec<u8> = (0..256).flat_map(|i| (i as f32 * 1.01).to_le_bytes()).collect();
        let wire = encode(Codec::Bf16, Lane::Factor, &payload);
        assert_eq!(wire[0], M_BF16);
        assert_eq!(wire.len(), CODEC_HEADER_BYTES + payload.len() / 2);
        let got = decode(&wire).unwrap();
        assert_ne!(got, payload); // lossy
        assert_eq!(got, lossy_roundtrip(Codec::Bf16, Lane::Factor, &payload).unwrap());
        // frame lane under a lossy codec stays bit-exact
        let frame = encode(Codec::Bf16, Lane::Frame, &payload);
        assert_eq!(decode(&frame).unwrap(), payload);
        // unaligned factor payloads fall back to bit-exact encoding
        let odd = vec![1u8, 2, 3];
        assert_eq!(decode(&encode(Codec::Bf16, Lane::Factor, &odd)).unwrap(), odd);
        assert!(lossy_roundtrip(Codec::Bf16, Lane::Factor, &odd).is_none());
        assert!(lossy_roundtrip(Codec::Lossless, Lane::Factor, &payload).is_none());
    }

    #[test]
    fn rle_handles_long_runs_and_literal_chunks() {
        let mut plane = vec![9u8; 1000];
        plane.extend((0..300).map(|i| (i * 13 % 251) as u8));
        let enc = rle_encode(&plane).unwrap();
        assert!(enc.len() < plane.len());
        assert_eq!(rle_decode(&enc, plane.len()).unwrap(), plane);
        assert!(rle_decode(&enc, plane.len() - 1).is_err());
    }

    #[test]
    fn huffman_rejects_tables_that_cannot_win() {
        assert!(huffman_encode(&[1, 2, 3]).is_none()); // too small
        let uniform: Vec<u8> = (0..4096).map(|i| (i % 256) as u8).collect();
        assert!(huffman_encode(&uniform).is_none()); // 8-bit codes + table
    }

    #[test]
    fn huffman_roundtrips_skewed_planes() {
        let mut rng = Rng::new(9);
        let plane: Vec<u8> =
            (0..5000).map(|_| if rng.below(10) < 8 { 0 } else { rng.below(16) as u8 }).collect();
        let enc = huffman_encode(&plane).unwrap();
        assert!(enc.len() < plane.len());
        assert_eq!(huffman_decode(&enc, plane.len()).unwrap(), plane);
    }

    #[test]
    fn codec_parse_and_names_roundtrip() {
        for c in [Codec::Off, Codec::Lossless, Codec::Bf16, Codec::F16] {
            assert_eq!(Codec::parse(c.name()).unwrap(), c);
        }
        assert!(Codec::parse("zstd").is_err());
        assert!(Codec::Bf16.is_lossy() && Codec::F16.is_lossy());
        assert!(!Codec::Off.is_lossy() && !Codec::Lossless.is_lossy());
    }
}
