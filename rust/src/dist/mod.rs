//! Real multi-rank data parallelism: pluggable transports, deterministic
//! ring-volume collectives, and the process group that runs one worker
//! thread per DP rank.
//!
//! This is the execution substrate behind `edgc train --dp N --transport
//! mem|tcp`: instead of averaging replica gradients inside one address
//! space (`coordinator::engine::Engine::allreduce`), each rank owns its
//! model replica, data shard and error-feedback state, and the PowerSGD
//! P/Q factors are all-reduced through a [`transport::Transport`] —
//! moving real bytes whose per-link counters calibrate the `netsim`
//! ring model (DESIGN.md §Distributed execution).
//!
//! * [`transport`] — `Transport` trait + in-process channel mesh and
//!   TCP-loopback mesh, per-link byte/message counters (data vs diag
//!   traffic classes, logical vs post-codec wire bytes)
//! * [`codec`] — the wire codec layer between collectives/p2p framing
//!   and the transports: bit-exact `lossless` plane-transpose entropy
//!   coding for every frame, lossy `bf16`/`f16` quantization of the
//!   PowerSGD factor lane (DESIGN.md §Layered wire stack)
//! * [`collective`] — chunked reduce-scatter / all-gather / broadcast
//!   over f32 slices; fixed chunk boundaries and rank-ordered folds
//!   make every result byte-identical to `compress::allreduce_mean`
//!   for any rank count
//! * [`group`] — `run_group`: scoped rank worker threads over a mesh,
//!   per-rank counter snapshots, rank-forked RNG streams
//! * [`error`] — typed transport failures ([`DistError`]): peer death,
//!   corrupt frames, receive timeouts — carried inside `EdgcError` so
//!   fault handling matches variants instead of grepping messages

pub mod codec;
pub mod collective;
pub mod error;
pub mod group;
pub mod transport;

pub use codec::{Codec, Lane};
pub use error::DistError;
pub use group::{run_group, run_group2, TransportKind};
pub use transport::{Class, Counters, SubTransport, Transport};
