//! Deterministic collectives over f32 slices — the dist counterpart of
//! `compress::allreduce_mean`, with ring-equivalent wire volume.
//!
//! The all-reduce is chunked reduce-scatter + all-gather: every vector
//! is split into `world` **fixed chunks** (boundaries are a pure
//! function of `(len, world)`, never of scheduling), rank `c` owns
//! chunk `c`, and the two phases run on a ring-offset exchange schedule
//! (step `s`: send to `rank+s`, receive from `rank−s`, mod `world`).
//! Per-rank traffic is the classic ring all-reduce volume,
//! `2(world−1)/world · len` floats; summed over the group it is exactly
//! `netsim::ring_wire_bytes` for any chunk split.
//!
//! **Determinism contract** (the repo-wide byte-identity rule): raw
//! contributions travel straight to the chunk owner — not as running
//! partial sums along a ring path — and the owner folds them
//! **in rank order, starting from zero**, then scales by `1/world`:
//! exactly the fold `compress::allreduce_mean` performs. A classic ring
//! accumulates along a rotated path per chunk, which is the same volume
//! but a different (rank-count-dependent) float grouping; this variant
//! trades neighbor-only links for byte-identical results at any rank
//! count, which is what lets `tests/determinism.rs` pin distributed
//! training to the centralized engine bit-for-bit.
//!
//! Both transports deliver per-link FIFO, and every receive names its
//! peer, so the fold inputs — hence the output bytes — are independent
//! of cross-link timing.
//!
//! **Wire codecs** (DESIGN.md §Layered wire stack) sit *below* this
//! layer, inside the transports: the collectives exchange logical f32
//! bytes and every identity above is stated in logical bytes, which is
//! also what the counters' `sent_bytes` record (`sent_wire_bytes`
//! carries the post-codec size). The one place a codec shows through is
//! lossiness: under `bf16`/`f16` on the factor lane, everything a rank
//! *keeps* that peers got through the wire must take the same
//! round-trip — [`all_gather`] passes its own chunk and [`broadcast_bytes`]
//! the root copy through `Transport::lossy_view`, so all ranks hold
//! byte-identical results. (`reduce_scatter_mean` folds the owner's own
//! contribution at full precision — that asymmetry is private to the
//! owner and leaves with the uniformly-quantized all-gather.)

use std::ops::Range;

use crate::dist::transport::Transport;
use crate::util::error::Result;
use crate::{bail, ensure};

/// The fixed boundaries of chunk `c` of `0..len` split `world` ways:
/// balanced split, the first `len % world` chunks one element longer.
/// Chunks may be empty when `len < world`.
pub fn chunk_range(len: usize, world: usize, c: usize) -> Range<usize> {
    debug_assert!(c < world);
    let base = len / world;
    let rem = len % world;
    let lo = c * base + c.min(rem);
    lo..lo + base + usize::from(c < rem)
}

fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    ensure!(b.len() % 4 == 0, "payload of {} bytes is not a f32 vector", b.len());
    Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// Point-to-point send of an f32 slice (pairs with [`recv_f32s`]).
/// The pipeline coordinator uses this for slice gathers (entropy
/// samples, parameter ranges) that ride on the mesh outside the
/// collectives.
pub fn send_f32s(tr: &mut dyn Transport, to: usize, xs: &[f32]) -> Result<()> {
    tr.send(to, &f32s_to_bytes(xs))
}

/// Point-to-point receive of an f32 vector from a specific peer.
pub fn recv_f32s(tr: &mut dyn Transport, from: usize) -> Result<Vec<f32>> {
    bytes_to_f32s(&tr.recv(from)?)
}

/// Reduce-scatter with mean: contributes `buf`, returns this rank's
/// owned reduced chunk (`chunk_range(len, world, rank)` of the mean).
/// Empty chunks move no messages — both sides derive the skip from the
/// fixed boundaries, so the schedule stays in lockstep.
pub fn reduce_scatter_mean(tr: &mut dyn Transport, buf: &[f32]) -> Result<Vec<f32>> {
    let (world, rank) = (tr.world(), tr.rank());
    let len = buf.len();
    // Send every chunk to its owner first (transports buffer, so the
    // whole send phase completes without waiting on any peer)...
    for s in 1..world {
        let to = (rank + s) % world;
        let r = chunk_range(len, world, to);
        if !r.is_empty() {
            tr.send(to, &f32s_to_bytes(&buf[r]))?;
        }
    }
    // ...then collect the k−1 foreign contributions for the owned chunk.
    let my = chunk_range(len, world, rank);
    let mut parts: Vec<Option<Vec<f32>>> = (0..world).map(|_| None).collect();
    if !my.is_empty() {
        for s in 1..world {
            let from = (rank + world - s) % world;
            let p = bytes_to_f32s(&tr.recv(from)?)?;
            ensure!(
                p.len() == my.len(),
                "rank {rank}: chunk from rank {from} has {} floats, expected {}",
                p.len(),
                my.len()
            );
            parts[from] = Some(p);
        }
    }
    // Fold in rank order from zero, then scale — the exact grouping of
    // compress::allreduce_mean, so the bytes match for any rank count.
    let mut acc = vec![0.0f32; my.len()];
    for r in 0..world {
        let src: &[f32] = if r == rank {
            &buf[my.clone()]
        } else {
            parts[r].as_deref().unwrap_or(&[])
        };
        for (a, &x) in acc.iter_mut().zip(src) {
            *a += x;
        }
    }
    let inv = 1.0 / world as f32;
    for a in &mut acc {
        *a *= inv;
    }
    Ok(acc)
}

/// All-gather of per-rank owned chunks back into the full vector:
/// `mine` must be this rank's `chunk_range(len, world, rank)` slice.
pub fn all_gather(tr: &mut dyn Transport, mine: &[f32], len: usize) -> Result<Vec<f32>> {
    let (world, rank) = (tr.world(), tr.rank());
    let my = chunk_range(len, world, rank);
    ensure!(mine.len() == my.len(), "own chunk has {} floats, expected {}", mine.len(), my.len());
    let payload = f32s_to_bytes(mine);
    for s in 1..world {
        let to = (rank + s) % world;
        if !my.is_empty() {
            tr.send(to, &payload)?;
        }
    }
    let mut out = vec![0.0f32; len];
    // Keep what we shipped: under a lossy codec, peers received the
    // quantized chunk, so the local copy must take the same round-trip
    // (skipped at world == 1, where nothing crosses a wire).
    match if world > 1 { tr.lossy_view(&payload) } else { None } {
        Some(w) => out[my].copy_from_slice(&bytes_to_f32s(&w)?),
        None => out[my].copy_from_slice(mine),
    }
    for s in 1..world {
        let from = (rank + world - s) % world;
        let r = chunk_range(len, world, from);
        if !r.is_empty() {
            let p = bytes_to_f32s(&tr.recv(from)?)?;
            ensure!(
                p.len() == r.len(),
                "rank {rank}: gathered chunk from rank {from} has {} floats, expected {}",
                p.len(),
                r.len()
            );
            out[r].copy_from_slice(&p);
        }
    }
    Ok(out)
}

/// In-place all-reduce mean over `buf`: reduce-scatter + all-gather.
/// Every rank ends with bytes identical to `compress::allreduce_mean`
/// over the group's `world` contributions.
pub fn all_reduce_mean(tr: &mut dyn Transport, buf: &mut [f32]) -> Result<()> {
    let mine = reduce_scatter_mean(tr, buf)?;
    let full = all_gather(tr, &mine, buf.len())?;
    buf.copy_from_slice(&full);
    Ok(())
}

/// Broadcast opaque bytes from `root`: the root passes `Some(payload)`,
/// every other rank passes `None`; all ranks return the payload.
pub fn broadcast_bytes(
    tr: &mut dyn Transport,
    root: usize,
    payload: Option<&[u8]>,
) -> Result<Vec<u8>> {
    let (world, rank) = (tr.world(), tr.rank());
    ensure!(root < world, "broadcast root {root} out of range (world {world})");
    if rank == root {
        let p = match payload {
            Some(p) => p,
            None => bail!("broadcast root must supply the payload"),
        };
        for peer in (0..world).filter(|&q| q != root) {
            tr.send(peer, p)?;
        }
        // Keep what we shipped (see all_gather): the root's returned
        // copy must match what peers decoded from the wire.
        match if world > 1 { tr.lossy_view(p) } else { None } {
            Some(w) => Ok(w),
            None => Ok(p.to_vec()),
        }
    } else {
        ensure!(payload.is_none(), "non-root rank {rank} supplied a broadcast payload");
        tr.recv(root)
    }
}

/// Broadcast an f32 buffer in place from `root`.
pub fn broadcast_f32(tr: &mut dyn Transport, root: usize, buf: &mut [f32]) -> Result<()> {
    let payload = if tr.rank() == root { Some(f32s_to_bytes(buf)) } else { None };
    let got = broadcast_bytes(tr, root, payload.as_deref())?;
    let xs = bytes_to_f32s(&got)?;
    ensure!(xs.len() == buf.len(), "broadcast of {} floats into {} slots", xs.len(), buf.len());
    buf.copy_from_slice(&xs);
    Ok(())
}

/// All-gather one f32 per rank (rank-indexed result on every rank).
pub fn all_gather_f32(tr: &mut dyn Transport, x: f32) -> Result<Vec<f32>> {
    Ok(all_gather_words(tr, &x.to_le_bytes())?
        .iter()
        .map(|w| f32::from_le_bytes([w[0], w[1], w[2], w[3]]))
        .collect())
}

/// All-gather one u64 per rank (rank-indexed result on every rank).
pub fn all_gather_u64(tr: &mut dyn Transport, x: u64) -> Result<Vec<u64>> {
    Ok(all_gather_words(tr, &x.to_le_bytes())?
        .iter()
        .map(|w| {
            let mut b = [0u8; 8];
            b.copy_from_slice(w);
            u64::from_le_bytes(b)
        })
        .collect())
}

/// Star-exchange of one fixed-width word per rank.
fn all_gather_words(tr: &mut dyn Transport, word: &[u8]) -> Result<Vec<Vec<u8>>> {
    let (world, rank) = (tr.world(), tr.rank());
    for peer in (0..world).filter(|&p| p != rank) {
        tr.send(peer, word)?;
    }
    let mut out: Vec<Vec<u8>> = vec![Vec::new(); world];
    out[rank] = word.to_vec();
    for peer in (0..world).filter(|&p| p != rank) {
        let w = tr.recv(peer)?;
        ensure!(w.len() == word.len(), "gathered word of {} bytes, want {}", w.len(), word.len());
        out[peer] = w;
    }
    Ok(out)
}

/// Gather every rank's f32 buffer to rank 0: the root returns
/// `Some(rank-indexed buffers)` (its own included), everyone else
/// `None`. Callers gating diagnostics switch the transport to
/// `Class::Diag` around this (see `compress::TensorCompressor::round_dist`).
pub fn gather_to_root(tr: &mut dyn Transport, buf: &[f32]) -> Result<Option<Vec<Vec<f32>>>> {
    let (world, rank) = (tr.world(), tr.rank());
    if rank != 0 {
        tr.send(0, &f32s_to_bytes(buf))?;
        return Ok(None);
    }
    let mut out: Vec<Vec<f32>> = Vec::with_capacity(world);
    out.push(buf.to_vec());
    for peer in 1..world {
        let p = bytes_to_f32s(&tr.recv(peer)?)?;
        ensure!(
            p.len() == buf.len(),
            "gathered buffer from rank {peer} has {} floats, expected {}",
            p.len(),
            buf.len()
        );
        out.push(p);
    }
    Ok(Some(out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::allreduce_mean;
    use crate::dist::transport::mem_mesh;

    #[test]
    fn chunk_ranges_cover_and_balance() {
        for &(len, world) in &[(10usize, 3usize), (7, 7), (3, 5), (0, 4), (16, 1)] {
            let mut covered = 0usize;
            for c in 0..world {
                let r = chunk_range(len, world, c);
                assert_eq!(r.start, covered, "len={len} world={world} c={c}");
                covered = r.end;
                assert!(r.len() <= len.div_ceil(world.max(1)));
            }
            assert_eq!(covered, len);
        }
    }

    /// Run `f` on every rank of a fresh mem mesh; results rank-indexed.
    fn on_mesh<R: Send>(
        world: usize,
        f: impl Fn(&mut dyn Transport) -> Result<R> + Sync,
    ) -> Vec<R> {
        let mesh = mem_mesh(world);
        let f = &f;
        std::thread::scope(|s| {
            let hs: Vec<_> = mesh
                .into_iter()
                .map(|mut t| s.spawn(move || f(&mut t).unwrap()))
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn all_reduce_mean_matches_centralized_bitwise() {
        use crate::util::rng::Rng;
        for &(world, len) in &[(1usize, 5usize), (2, 8), (3, 10), (4, 3), (5, 17)] {
            let grads: Vec<Vec<f32>> =
                (0..world).map(|r| Rng::new(100 + r as u64).normal_vec(len, 1.0)).collect();
            let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
            let (want, _) = allreduce_mean(&refs);
            let got = on_mesh(world, |tr| {
                let mut b = grads[tr.rank()].clone();
                all_reduce_mean(tr, &mut b)?;
                Ok(b)
            });
            for (rank, g) in got.iter().enumerate() {
                let same = g.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "world={world} len={len} rank={rank}");
            }
        }
    }

    #[test]
    fn all_reduce_wire_volume_is_exactly_ring() {
        // Total data-class *logical* payload across the group =
        // 2(k−1)·4·len bytes for any chunk split (the netsim calibration
        // identity; codecs only move the wire-byte counter).
        for &(world, len) in &[(2usize, 9usize), (4, 10), (5, 3)] {
            let sent: u64 = on_mesh(world, |tr| {
                let mut b = vec![1.0f32; len];
                all_reduce_mean(tr, &mut b)?;
                Ok(tr.counters().data_sent_bytes())
            })
            .iter()
            .sum();
            let want = crate::netsim::ring_wire_bytes(world, len);
            assert_eq!(sent as f64, want, "world={world} len={len}");
        }
    }

    #[test]
    fn lossless_codec_is_bit_exact_with_logical_identity() {
        use crate::dist::codec::Codec;
        use crate::util::rng::Rng;
        // Includes a len < world case (empty chunks) — the codec must
        // not disturb the lockstep schedule or the logical-byte identity.
        for &(world, len) in &[(2usize, 4096usize), (3, 10), (5, 3)] {
            let grads: Vec<Vec<f32>> =
                (0..world).map(|r| Rng::new(300 + r as u64).normal_vec(len, 1.0)).collect();
            let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
            let (want, _) = allreduce_mean(&refs);
            let got = on_mesh(world, |tr| {
                tr.set_codec(Codec::Lossless);
                let mut b = grads[tr.rank()].clone();
                all_reduce_mean(tr, &mut b)?;
                Ok((b, tr.counters().data_sent_bytes(), tr.counters().data_sent_wire_bytes()))
            });
            for (rank, (g, _, _)) in got.iter().enumerate() {
                let same = g.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "world={world} len={len} rank={rank}");
            }
            let logical: u64 = got.iter().map(|(_, l, _)| *l).sum();
            assert_eq!(logical as f64, crate::netsim::ring_wire_bytes(world, len));
            if len >= 4096 {
                let wire: u64 = got.iter().map(|(_, _, w)| *w).sum();
                assert!(wire < logical, "wire {wire} >= logical {logical}");
            }
        }
    }

    #[test]
    fn bf16_factor_allreduce_keeps_ranks_in_lockstep() {
        use crate::dist::codec::{Codec, Lane};
        use crate::util::rng::Rng;
        for &(world, len) in &[(2usize, 33usize), (4, 10)] {
            let grads: Vec<Vec<f32>> =
                (0..world).map(|r| Rng::new(500 + r as u64).normal_vec(len, 1.0)).collect();
            let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
            let (exact, _) = allreduce_mean(&refs);
            let got = on_mesh(world, |tr| {
                tr.set_codec(Codec::Bf16);
                tr.set_lane(Lane::Factor);
                let mut b = grads[tr.rank()].clone();
                all_reduce_mean(tr, &mut b)?;
                tr.set_lane(Lane::Frame);
                Ok(b)
            });
            // the lossy_view round-trip keeps every rank byte-identical
            for (rank, g) in got.iter().enumerate() {
                let same = g.iter().zip(&got[0]).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "world={world} len={len} rank={rank} diverged");
            }
            // close to the exact mean (contributions and the gathered
            // chunks each carry ≤ 2⁻⁸ relative quantization error) ...
            for (a, b) in got[0].iter().zip(&exact) {
                assert!((a - b).abs() <= b.abs() / 64.0 + 0.05, "bf16 mean {a} vs exact {b}");
            }
            // ... but genuinely quantized, not silently bit-exact
            assert!(got[0].iter().zip(&exact).any(|(a, b)| a.to_bits() != b.to_bits()));
        }
    }

    #[test]
    fn broadcast_reaches_everyone() {
        let got = on_mesh(3, |tr| {
            let payload = if tr.rank() == 1 { Some(&b"hello"[..]) } else { None };
            broadcast_bytes(tr, 1, payload)
        });
        assert!(got.iter().all(|g| g == b"hello"));
        let nums = on_mesh(4, |tr| {
            let mut buf = if tr.rank() == 0 { vec![1.5f32, -2.0] } else { vec![0.0; 2] };
            broadcast_f32(tr, 0, &mut buf)?;
            Ok(buf)
        });
        assert!(nums.iter().all(|b| b == &[1.5, -2.0]));
    }

    #[test]
    fn scalar_and_word_gathers_are_rank_indexed() {
        let fs = on_mesh(4, |tr| all_gather_f32(tr, tr.rank() as f32 * 2.0));
        assert!(fs.iter().all(|v| v == &[0.0, 2.0, 4.0, 6.0]));
        let us = on_mesh(3, |tr| all_gather_u64(tr, 10 + tr.rank() as u64));
        assert!(us.iter().all(|v| v == &[10, 11, 12]));
    }

    #[test]
    fn gather_to_root_orders_by_rank() {
        let got = on_mesh(3, |tr| {
            let buf = vec![tr.rank() as f32; 4];
            gather_to_root(tr, &buf)
        });
        let root = got[0].as_ref().unwrap();
        assert_eq!(root.len(), 3);
        for (r, b) in root.iter().enumerate() {
            assert_eq!(b, &vec![r as f32; 4]);
        }
        assert!(got[1].is_none() && got[2].is_none());
    }
}
